//! Trace CSV I/O: `id,arrival,duration,a,b,c,comm_frac[,priority]` — a
//! drop-in slot for real (e.g. Philly-derived) traces. The `priority`
//! column is optional on read (absent → class 0) and written only when
//! some job actually carries a non-default class, so priority-free traces
//! round-trip byte-identically to the 7-column format.

use std::io::{BufRead, Write};
use std::path::Path;

use super::JobSpec;
use crate::shape::JobShape;

/// Serialize a trace to CSV (with header).
pub fn write_csv(path: &Path, trace: &[JobSpec]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let with_priority = trace.iter().any(|j| j.priority != 0);
    if with_priority {
        writeln!(f, "id,arrival,duration,a,b,c,comm_frac,priority")?;
    } else {
        writeln!(f, "id,arrival,duration,a,b,c,comm_frac")?;
    }
    for j in trace {
        let d = j.shape.dims();
        write!(
            f,
            "{},{:.3},{:.3},{},{},{},{:.4}",
            j.id, j.arrival, j.duration, d.0[0], d.0[1], d.0[2], j.comm_frac
        )?;
        if with_priority {
            write!(f, ",{}", j.priority)?;
        }
        writeln!(f)?;
    }
    Ok(())
}

/// Parse a trace from CSV (header required).
pub fn read_csv(path: &Path) -> std::io::Result<Vec<JobSpec>> {
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut out = Vec::new();
    for (lineno, line) in f.lines().enumerate() {
        let line = line?;
        if lineno == 0 || line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.trim().split(',').collect();
        if cols.len() != 7 && cols.len() != 8 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!(
                    "line {}: expected 7 or 8 columns, got {}",
                    lineno + 1,
                    cols.len()
                ),
            ));
        }
        let parse_err = |what: &str| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("line {}: bad {what}", lineno + 1),
            )
        };
        out.push(JobSpec {
            id: cols[0].parse().map_err(|_| parse_err("id"))?,
            arrival: cols[1].parse().map_err(|_| parse_err("arrival"))?,
            duration: cols[2].parse().map_err(|_| parse_err("duration"))?,
            shape: JobShape::new(
                cols[3].parse().map_err(|_| parse_err("a"))?,
                cols[4].parse().map_err(|_| parse_err("b"))?,
                cols[5].parse().map_err(|_| parse_err("c"))?,
            ),
            comm_frac: cols[6].parse().map_err(|_| parse_err("comm_frac"))?,
            priority: match cols.get(7) {
                Some(p) => p.parse().map_err(|_| parse_err("priority"))?,
                None => 0,
            },
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::{generate, TraceConfig};

    #[test]
    fn roundtrip() {
        let trace = generate(&TraceConfig { num_jobs: 40, ..Default::default() });
        let tmp = std::env::temp_dir().join("rfold_trace_test.csv");
        write_csv(&tmp, &trace).unwrap();
        let back = read_csv(&tmp).unwrap();
        assert_eq!(trace.len(), back.len());
        for (a, b) in trace.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.shape, b.shape);
            assert!((a.arrival - b.arrival).abs() < 1e-3);
            assert!((a.duration - b.duration).abs() < 1e-3);
        }
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn rejects_malformed() {
        let tmp = std::env::temp_dir().join("rfold_trace_bad.csv");
        std::fs::write(&tmp, "id,arrival\n1,2\n").unwrap();
        assert!(read_csv(&tmp).is_err());
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn priority_column_roundtrips_and_defaults() {
        let mut trace = generate(&TraceConfig { num_jobs: 6, ..Default::default() });
        // Priority-free traces stay on the legacy 7-column format.
        let tmp = std::env::temp_dir().join("rfold_trace_prio_free.csv");
        write_csv(&tmp, &trace).unwrap();
        let head = std::fs::read_to_string(&tmp).unwrap();
        assert!(head.starts_with("id,arrival,duration,a,b,c,comm_frac\n"));
        assert!(read_csv(&tmp).unwrap().iter().all(|j| j.priority == 0));
        std::fs::remove_file(&tmp).ok();

        // A trace with classes writes and reads back the 8th column.
        trace[2].priority = 3;
        trace[4].priority = 1;
        let tmp = std::env::temp_dir().join("rfold_trace_prio.csv");
        write_csv(&tmp, &trace).unwrap();
        let head = std::fs::read_to_string(&tmp).unwrap();
        assert!(head.starts_with("id,arrival,duration,a,b,c,comm_frac,priority\n"));
        let back = read_csv(&tmp).unwrap();
        for (a, b) in trace.iter().zip(&back) {
            assert_eq!(a.priority, b.priority);
        }
        std::fs::remove_file(&tmp).ok();
    }
}
