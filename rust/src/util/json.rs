//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Supports the subset needed for `artifacts/manifest.json` and result
//! dumps: objects, arrays, strings (with escapes), numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// A `u64` carried as a decimal string. JSON numbers travel as `f64`
    /// here, which silently corrupts integers above 2^53 — seeds and job
    /// ids must survive the wire exactly, so they ride as strings.
    pub fn u64_str(v: u64) -> Json {
        Json::Str(v.to_string())
    }

    /// Read back a [`Json::u64_str`] value.
    pub fn as_u64_str(&self) -> Option<u64> {
        self.as_str().and_then(|s| s.parse().ok())
    }

    /// An `f64` carried bit-exactly as its IEEE-754 bit pattern in a
    /// decimal string. The distributed sweep's determinism contract is
    /// *byte*-identical rows for any backend, so wire floats must
    /// round-trip exactly — including NaN payloads, which no decimal
    /// rendering preserves.
    pub fn f64_bits(v: f64) -> Json {
        Json::Str(v.to_bits().to_string())
    }

    /// Read back a [`Json::f64_bits`] value.
    pub fn as_f64_bits(&self) -> Option<f64> {
        self.as_str()
            .and_then(|s| s.parse::<u64>().ok())
            .map(f64::from_bits)
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(JsonError::Trailing(p.i));
        }
        Ok(v)
    }
}

/// Parse error with byte offset context.
#[derive(Debug, PartialEq, Eq)]
pub enum JsonError {
    Eof,
    Unexpected(usize),
    Trailing(usize),
    BadNumber(usize),
    BadEscape(usize),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Eof => write!(f, "unexpected end of input"),
            JsonError::Unexpected(o) => write!(f, "unexpected byte at offset {o}"),
            JsonError::Trailing(o) => write!(f, "trailing garbage at offset {o}"),
            JsonError::BadNumber(o) => write!(f, "bad number at offset {o}"),
            JsonError::BadEscape(o) => write!(f, "bad escape at offset {o}"),
        }
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8, JsonError> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof)
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::Unexpected(self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.i))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(JsonError::Unexpected(self.i)),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(JsonError::Eof);
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(JsonError::BadEscape(self.i - 1)),
                    }
                }
                _ => out.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError::BadNumber(start))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(JsonError::Unexpected(self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(JsonError::Unexpected(self.i)),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let t = r#"{"plan_batch": 64, "torus": [16, 16, 16],
                    "modules": {"m": {"file": "m.hlo.txt", "cubes": 64}}}"#;
        let j = Json::parse(t).unwrap();
        assert_eq!(j.get("plan_batch").unwrap().as_usize(), Some(64));
        assert_eq!(j.get("torus").unwrap().as_arr().unwrap().len(), 3);
        let m = j.get("modules").unwrap().get("m").unwrap();
        assert_eq!(m.get("file").unwrap().as_str(), Some("m.hlo.txt"));
    }

    #[test]
    fn roundtrip() {
        let t = r#"{"a":[1,2.5,true,null,"x\n"],"b":{"c":-3}}"#;
        let j = Json::parse(t).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_trailing() {
        assert!(matches!(Json::parse("1 x"), Err(JsonError::Trailing(_))));
    }

    #[test]
    fn rejects_eof() {
        assert_eq!(Json::parse("[1,"), Err(JsonError::Eof));
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""A""#).unwrap();
        assert_eq!(j.as_str(), Some("A"));
    }

    #[test]
    fn number_formats() {
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn u64_str_roundtrips_above_2_pow_53() {
        let v = u64::MAX - 3; // would corrupt through an f64
        let j = Json::parse(&Json::u64_str(v).to_string()).unwrap();
        assert_eq!(j.as_u64_str(), Some(v));
        assert_eq!(Json::Num(1.0).as_u64_str(), None);
    }

    #[test]
    fn f64_bits_roundtrip_exactly() {
        for v in [0.0, -0.0, 1.5, f64::MIN_POSITIVE, f64::MAX, f64::NAN, f64::INFINITY] {
            let j = Json::parse(&Json::f64_bits(v).to_string()).unwrap();
            let back = j.as_f64_bits().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{v}");
        }
    }
}
