//! Small statistics helpers shared by metrics, benches and tests.

/// Linear-interpolation percentile (same convention as numpy's default).
/// `p` in [0, 100]. Returns NaN for an empty slice.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Sort a copy and take a percentile.
pub fn percentile_of(values: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&v, p)
}

/// Arithmetic mean; NaN for empty input.
pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

/// Population standard deviation.
pub fn stddev(values: &[f64]) -> f64 {
    if values.len() < 2 {
        return 0.0;
    }
    let m = mean(values);
    (values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / values.len() as f64).sqrt()
}

/// An empirical CDF over weighted samples (used for the utilization
/// time-series, where the weight of a sample is the wall-clock time the
/// cluster spent at that utilization level).
///
/// Quantile queries go through a lazily built sorted/prefix-sum index,
/// computed once per sample set and invalidated on [`WeightedCdf::push`]
/// — so `curve(20)` costs one sort, not 21 (this sits on the utilization
/// summary hot path of every sweep trial).
#[derive(Clone, Debug, Default)]
pub struct WeightedCdf {
    /// (value, weight) pairs, in insertion order.
    samples: Vec<(f64, f64)>,
    /// Lazy quantile index; `OnceLock` keeps queries `&self` while the
    /// value stays `Sync` for cross-thread result collection.
    index: std::sync::OnceLock<CdfIndex>,
}

/// Sorted samples plus running weight sums, accumulated in sorted order —
/// the exact fold order the pre-index implementation used per query, so
/// quantile output stays byte-identical.
#[derive(Clone, Debug)]
struct CdfIndex {
    sorted: Vec<(f64, f64)>,
    /// `prefix[i]` = sum of `sorted[..=i]` weights.
    prefix: Vec<f64>,
}

impl WeightedCdf {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, value: f64, weight: f64) {
        if weight > 0.0 {
            self.samples.push((value, weight));
            self.index.take(); // sample set changed: rebuild on next query
        }
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// The raw `(value, weight)` samples in insertion order — the full
    /// state of the CDF (the quantile index is derived), which is what
    /// the distributed sweep ships over the wire.
    pub fn samples(&self) -> &[(f64, f64)] {
        &self.samples
    }

    /// Rebuild a CDF from samples previously read via
    /// [`WeightedCdf::samples`]. Zero/negative-weight entries are dropped
    /// exactly as [`WeightedCdf::push`] would drop them, so a wire
    /// round-trip is state-identical and quantiles stay byte-identical.
    pub fn from_samples(samples: Vec<(f64, f64)>) -> Self {
        let mut cdf = WeightedCdf::new();
        for (v, w) in samples {
            cdf.push(v, w);
        }
        cdf
    }

    pub fn total_weight(&self) -> f64 {
        self.samples.iter().map(|s| s.1).sum()
    }

    /// Approximate heap footprint of the sample set — lets the sweep
    /// result cache bound itself by bytes. Always charges for the lazy
    /// quantile index (sorted pairs + prefix sums) whether or not it is
    /// built yet: cached entries get their index built *after* insertion
    /// (during summarization), so a state-dependent measure would both
    /// undercount resident memory and drift on re-insertion.
    pub fn approx_bytes(&self) -> usize {
        let pair = std::mem::size_of::<(f64, f64)>();
        self.samples.capacity() * pair
            + self.samples.len() * (pair + std::mem::size_of::<f64>())
    }

    fn index(&self) -> &CdfIndex {
        self.index.get_or_init(|| {
            let mut sorted = self.samples.clone();
            sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let mut prefix = Vec::with_capacity(sorted.len());
            let mut acc = 0.0f64;
            for &(_, w) in &sorted {
                acc += w;
                prefix.push(acc);
            }
            CdfIndex { sorted, prefix }
        })
    }

    /// Value at the given cumulative fraction `q` in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let idx = self.index();
        let total = *idx.prefix.last().unwrap();
        let target = q.clamp(0.0, 1.0) * total;
        // First sample whose running weight reaches the target (weights
        // are strictly positive, so `prefix` is strictly increasing).
        let i = idx.prefix.partition_point(|&acc| acc < target);
        match idx.sorted.get(i) {
            Some(&(v, _)) => v,
            None => idx.sorted.last().unwrap().0,
        }
    }

    /// Weighted mean of the sample values.
    pub fn mean(&self) -> f64 {
        let total = self.total_weight();
        if total == 0.0 {
            return f64::NAN;
        }
        self.samples.iter().map(|(v, w)| v * w).sum::<f64>() / total
    }

    /// Evaluate the CDF at a grid of `n+1` evenly spaced quantiles
    /// (q=0/n .. n/n) — the series plotted in Figure 4.
    pub fn curve(&self, n: usize) -> Vec<(f64, f64)> {
        (0..=n)
            .map(|i| {
                let q = i as f64 / n as f64;
                (q, self.quantile(q))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert_eq!(percentile(&v, 25.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 90.0) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_is_nan() {
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_of_unsorted() {
        assert_eq!(percentile_of(&[5.0, 1.0, 3.0], 50.0), 3.0);
    }

    #[test]
    fn mean_and_stddev() {
        let v = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&v) - 5.0).abs() < 1e-12);
        assert!((stddev(&v) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_cdf_quantiles() {
        let mut cdf = WeightedCdf::new();
        cdf.push(0.0, 1.0);
        cdf.push(1.0, 1.0);
        cdf.push(2.0, 2.0);
        assert_eq!(cdf.quantile(0.0), 0.0);
        assert_eq!(cdf.quantile(0.25), 0.0);
        assert_eq!(cdf.quantile(0.5), 1.0);
        assert_eq!(cdf.quantile(1.0), 2.0);
        assert!((cdf.mean() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn weighted_cdf_ignores_zero_weight() {
        let mut cdf = WeightedCdf::new();
        cdf.push(5.0, 0.0);
        assert!(cdf.is_empty());
    }

    /// The pre-index implementation, kept as a test oracle: sort + linear
    /// accumulate per query.
    fn quantile_reference(samples: &[(f64, f64)], q: f64) -> f64 {
        if samples.is_empty() {
            return f64::NAN;
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let total: f64 = s.iter().map(|x| x.1).sum();
        let target = q.clamp(0.0, 1.0) * total;
        let mut acc = 0.0;
        for (v, w) in &s {
            acc += w;
            if acc >= target {
                return *v;
            }
        }
        s.last().unwrap().0
    }

    #[test]
    fn indexed_quantiles_match_reference_exactly() {
        let mut cdf = WeightedCdf::new();
        let mut samples = Vec::new();
        let mut r = crate::util::Pcg64::seeded(11);
        for _ in 0..500 {
            let (v, w) = (r.f64(), r.f64() + 1e-3);
            cdf.push(v, w);
            samples.push((v, w));
        }
        for i in 0..=100 {
            let q = i as f64 / 100.0;
            // Bit-identical, not approximately equal: summaries feed the
            // byte-compared SWEEP rows.
            assert_eq!(
                cdf.quantile(q).to_bits(),
                quantile_reference(&samples, q).to_bits(),
                "q={q}"
            );
        }
    }

    #[test]
    fn push_invalidates_quantile_index() {
        let mut cdf = WeightedCdf::new();
        cdf.push(1.0, 1.0);
        assert_eq!(cdf.quantile(1.0), 1.0); // builds the index
        cdf.push(5.0, 10.0); // must invalidate it
        assert_eq!(cdf.quantile(1.0), 5.0);
        assert_eq!(cdf.quantile(0.0), 1.0);
    }

    #[test]
    fn samples_roundtrip_preserves_quantiles() {
        let mut cdf = WeightedCdf::new();
        let mut r = crate::util::Pcg64::seeded(23);
        for _ in 0..200 {
            cdf.push(r.f64(), r.f64() + 1e-3);
        }
        let back = WeightedCdf::from_samples(cdf.samples().to_vec());
        for i in 0..=50 {
            let q = i as f64 / 50.0;
            assert_eq!(cdf.quantile(q).to_bits(), back.quantile(q).to_bits());
        }
        assert_eq!(cdf.mean().to_bits(), back.mean().to_bits());
    }

    #[test]
    fn curve_is_monotone() {
        let mut cdf = WeightedCdf::new();
        let mut r = crate::util::Pcg64::seeded(5);
        for _ in 0..100 {
            cdf.push(r.f64(), r.f64() + 0.01);
        }
        let c = cdf.curve(20);
        for w in c.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }
}
