//! Minimal `anyhow`-style error handling (anyhow is unavailable offline).
//!
//! Provides the subset the runtime layer uses: a string-backed [`Error`],
//! a defaulted [`Result`] alias, the [`Context`] extension trait, and the
//! crate-root `anyhow!` / `bail!` / `ensure!` macros.

use std::fmt;

/// An opaque error carrying a human-readable message chain.
///
/// Like `anyhow::Error`, this deliberately does **not** implement
/// `std::error::Error` so the blanket `From<E: std::error::Error>` impl
/// below stays coherent with `core`'s reflexive `From`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Build an error from preformatted arguments (used by `anyhow!`).
    pub fn from_fmt(args: fmt::Arguments<'_>) -> Error {
        Error {
            msg: args.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulted to [`Error`], mirroring `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format_args!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format_args!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::from_fmt(::core::format_args!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::util::error::Error::from_fmt(::core::format_args!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($tok:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($tok)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($tok:tt)*) => {
        if !($cond) {
            $crate::bail!($($tok)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anyhow_macro_formats() {
        let e = crate::anyhow!("bad thing: {}", 7);
        assert_eq!(e.to_string(), "bad thing: 7");
        let plain = crate::anyhow!("plain");
        assert_eq!(plain.to_string(), "plain");
    }

    #[test]
    fn bail_returns_err() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
    }

    #[test]
    fn context_wraps_errors_and_options() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("writing report").unwrap_err();
        assert!(e.to_string().starts_with("writing report: "));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn from_std_error() {
        fn f() -> Result<String> {
            let bytes = vec![0xff, 0xfe];
            Ok(String::from_utf8(bytes)?)
        }
        assert!(f().unwrap_err().to_string().contains("utf-8"));
    }
}
