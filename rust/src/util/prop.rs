//! Lightweight property-based testing (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` seeded random inputs; on failure it
//! reports the failing seed so the case replays deterministically:
//!
//! ```
//! use rfold::util::prop;
//! prop::check("sum is commutative", 100, |rng| {
//!     let a = rng.below(1000) as i64;
//!     let b = rng.below(1000) as i64;
//!     prop::expect(a + b == b + a, format!("{a} {b}"))
//! });
//! ```

use crate::util::Pcg64;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assert helper returning a `PropResult`.
pub fn expect(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `prop` for `cases` deterministic cases. Panics (with the replay
/// seed) on the first failure. Base seed can be overridden with the
/// `RFOLD_PROP_SEED` environment variable to replay a specific failure.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> PropResult,
{
    let base: u64 = std::env::var("RFOLD_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed);
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut rng = Pcg64::new(seed, 0xA5A5u64 + case);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} \
                 (replay with RFOLD_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("below stays below", 50, |rng| {
            let n = rng.range(1, 100);
            let x = rng.below(n);
            expect(x < n, format!("x={x} n={n}"))
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics() {
        check("always fails", 3, |_| Err("nope".into()));
    }
}
