//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs binaries with `harness = false`; they use this module
//! to time closures with warmup, report mean/p50/p99 per iteration, and
//! print machine-greppable `BENCH` lines consumed by EXPERIMENTS.md.

use std::time::Instant;

/// Timing summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "BENCH {:<48} iters={:<6} mean={:>12} p50={:>12} p99={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
        );
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.2}s", ns / 1_000_000_000.0)
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed ones.
/// A black-box sink prevents the optimizer from deleting the work: have `f`
/// return something and it is consumed via `std::hint::black_box`.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: crate::util::stats::percentile(&samples, 50.0),
        p99_ns: crate::util::stats::percentile(&samples, 99.0),
    };
    r.print();
    r
}

/// Print a section header so bench output reads like the paper's tables.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 2, 10, || {
            (0..100u64).sum::<u64>()
        });
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
        assert_eq!(r.iters, 10);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("us"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5_000_000_000.0).ends_with('s'));
    }
}
