//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs binaries with `harness = false`; they use this module
//! to time closures with warmup, report mean/p50/p99 per iteration, and
//! print machine-greppable `BENCH` lines consumed by EXPERIMENTS.md.
//!
//! Two environment knobs make the harness CI-friendly:
//!
//! * `BENCH_SMOKE=1` — truncate warmup/iteration counts to a handful via
//!   [`smoke_iters`], so a bench binary doubles as a seconds-long CI
//!   smoke run (numbers are noisy but present);
//! * `BENCH_JSON=<path>` — benches that collect their [`BenchResult`]s
//!   call [`write_json_env`] at exit to emit one JSON object per line
//!   (`name`, `iters`, `ns_per_iter`, `p50_ns`, `p99_ns`), giving CI a
//!   machine-readable perf trajectory across PRs.

use std::time::Instant;

use crate::util::json::Json;

/// Timing summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "BENCH {:<48} iters={:<6} mean={:>12} p50={:>12} p99={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
        );
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.2}s", ns / 1_000_000_000.0)
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed ones.
/// A black-box sink prevents the optimizer from deleting the work: have `f`
/// return something and it is consumed via `std::hint::black_box`.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: crate::util::stats::percentile(&samples, 50.0),
        p99_ns: crate::util::stats::percentile(&samples, 99.0),
    };
    r.print();
    r
}

/// Print a section header so bench output reads like the paper's tables.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Is this a `BENCH_SMOKE=1` run (CI smoke: tiny iteration counts)?
pub fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some_and(|v| !v.is_empty() && v != "0")
}

/// Iteration count to actually run: `n` normally, at most 3 (and at
/// least 1) under `BENCH_SMOKE=1`.
pub fn smoke_iters(n: usize) -> usize {
    if smoke() {
        n.clamp(1, 3)
    } else {
        n
    }
}

/// One machine-readable row per result (JSON lines): `name`, `iters`,
/// `ns_per_iter` (the mean), plus the `p50_ns`/`p99_ns` spread.
pub fn results_json(results: &[BenchResult]) -> String {
    let mut out = String::new();
    for r in results {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(r.name.clone()));
        obj.insert("iters".to_string(), Json::Num(r.iters as f64));
        obj.insert("ns_per_iter".to_string(), Json::Num(r.mean_ns));
        obj.insert("p50_ns".to_string(), Json::Num(r.p50_ns));
        obj.insert("p99_ns".to_string(), Json::Num(r.p99_ns));
        out.push_str(&Json::Obj(obj).to_string());
        out.push('\n');
    }
    out
}

/// Write [`results_json`] rows to the path named by `BENCH_JSON`, if set.
/// Returns the path written to. I/O failures are loud (a CI perf row
/// silently missing is worse than a failed step).
pub fn write_json_env(results: &[BenchResult]) -> Option<String> {
    let path = std::env::var("BENCH_JSON").ok().filter(|p| !p.is_empty())?;
    std::fs::write(&path, results_json(results))
        .unwrap_or_else(|e| panic!("BENCH_JSON: cannot write {path}: {e}"));
    eprintln!("bench: wrote {} JSON rows to {path}", results.len());
    Some(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 2, 10, || {
            (0..100u64).sum::<u64>()
        });
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
        assert_eq!(r.iters, 10);
    }

    #[test]
    fn json_rows_roundtrip() {
        let rows = vec![BenchResult {
            name: "case a".into(),
            iters: 7,
            mean_ns: 1234.5,
            p50_ns: 1200.0,
            p99_ns: 2000.0,
        }];
        let text = results_json(&rows);
        assert_eq!(text.lines().count(), 1);
        let j = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(j.get("name").and_then(Json::as_str), Some("case a"));
        assert_eq!(j.get("iters").and_then(Json::as_usize), Some(7));
        assert_eq!(j.get("ns_per_iter").and_then(Json::as_f64), Some(1234.5));
    }

    #[test]
    fn smoke_iters_clamps_only_under_env() {
        // The env var is process-global; only assert the pure logic for
        // the current environment state.
        if smoke() {
            assert_eq!(smoke_iters(200), 3);
            assert_eq!(smoke_iters(0), 1);
        } else {
            assert_eq!(smoke_iters(200), 200);
        }
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("us"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5_000_000_000.0).ends_with('s'));
    }
}
