//! Deterministic PRNG: PCG-XSL-RR 128/64 ("pcg64").
//!
//! All experiments are seeded, so every table and figure regenerates
//! bit-identically; this matters more than raw speed here, but PCG is also
//! fast enough that trace generation never shows up in profiles.

/// PCG-XSL-RR 128/64. Matches the reference constants of the pcg64 member
/// of the PCG family (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Raw `(state, inc)` pair for snapshotting. Together with
    /// [`from_raw_state`](Self::from_raw_state) this captures the exact
    /// stream position: the restored generator's draws continue the
    /// original sequence bit-identically.
    pub fn raw_state(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`raw_state`](Self::raw_state) output.
    pub fn from_raw_state(state: u128, inc: u128) -> Self {
        Pcg64 { state, inc }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for unbiasedness.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Truncated exponential on `[lo, hi]` with the given scale, via
    /// inverse-CDF sampling (exact, no rejection loop).
    pub fn trunc_exponential(&mut self, scale: f64, lo: f64, hi: f64) -> f64 {
        let a = (-lo / scale).exp();
        let b = (-hi / scale).exp();
        let u = self.f64();
        let v = a + u * (b - a);
        -scale * v.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn raw_state_round_trips_mid_stream() {
        let mut a = Pcg64::new(42, 7);
        for _ in 0..13 {
            a.next_u64();
        }
        let (state, inc) = a.raw_state();
        let mut b = Pcg64::from_raw_state(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::seeded(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Pcg64::seeded(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn trunc_exponential_within_bounds() {
        let mut r = Pcg64::seeded(11);
        for _ in 0..5000 {
            let x = r.trunc_exponential(512.0, 1.0, 4096.0);
            assert!((1.0..=4096.0).contains(&x), "x={x}");
        }
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Pcg64::seeded(13);
        let n = 200_000;
        let s: f64 = (0..n).map(|_| r.exponential(10.0)).sum();
        let mean = s / n as f64;
        assert!((mean - 10.0).abs() < 0.2, "mean={mean}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Pcg64::seeded(17);
        for _ in 0..1000 {
            assert!(r.lognormal(1.0, 1.5) > 0.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(19);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
