//! In-tree replacements for crates unavailable in this offline environment
//! (rand, serde, clap, criterion, proptest, anyhow) plus shared numeric
//! helpers.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Pcg64;
