//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! plus policy-name resolution through the global
//! [`PolicyRegistry`](crate::placement::PolicyRegistry) — the single
//! point where CLI strings become [`PolicyHandle`]s.

use std::collections::BTreeMap;

use crate::placement::{PolicyHandle, PolicyRegistry};

/// Parsed arguments: options by name plus positionals in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclude argv[0]).
    /// `flag_names` lists option names that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        out.flags.push(body.to_string());
                    } else {
                        let v = it.next().unwrap();
                        out.opts.insert(body.to_string(), v);
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.pos.push(a);
            }
        }
        out
    }

    /// Parse the process arguments after the subcommand position.
    pub fn from_env(skip: usize, flag_names: &[&str]) -> Args {
        Args::parse(std::env::args().skip(skip), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parse `--<name>` as a duration in seconds (`500ms`/`5s`/`2m`/`1h`
    /// suffixes, bare numbers are seconds); `default` when absent. `Err`
    /// carries a ready-to-print message naming the option.
    pub fn get_duration(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_duration_secs(v).map_err(|e| format!("--{name}: {e}")),
        }
    }
}

/// Parse a duration with an optional `ms`/`s`/`m`/`h` suffix into seconds
/// (bare numbers are seconds). CLI-boundary twin of the `--with` modifier
/// duration syntax; kept here so `util` stays dependency-free.
pub fn parse_duration_secs(v: &str) -> Result<f64, String> {
    let (num, mult) = if let Some(n) = v.strip_suffix("ms") {
        (n, 1e-3)
    } else if let Some(n) = v.strip_suffix('s') {
        (n, 1.0)
    } else if let Some(n) = v.strip_suffix('m') {
        (n, 60.0)
    } else if let Some(n) = v.strip_suffix('h') {
        (n, 3600.0)
    } else {
        (v, 1.0)
    };
    let x: f64 = num
        .trim()
        .parse()
        .map_err(|_| format!("malformed duration '{v}' (use e.g. 500ms, 5s, 2m, 1h)"))?;
    if !x.is_finite() || x < 0.0 {
        return Err(format!("duration '{v}' must be finite and >= 0"));
    }
    Ok(x * mult)
}

impl Args {
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.pos.get(i).map(|s| s.as_str())
    }

    pub fn positionals(&self) -> &[String] {
        &self.pos
    }

    /// Resolve `--<name>` through the global policy registry; `default`
    /// when absent. `Err` carries a ready-to-print message listing the
    /// known policies.
    pub fn get_policy(&self, name: &str, default: PolicyHandle) -> Result<PolicyHandle, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => PolicyRegistry::global().resolve(s).ok_or_else(|| {
                format!(
                    "unknown policy '{s}' in --{name}; known: {}",
                    PolicyRegistry::global().known_keys()
                )
            }),
        }
    }

    /// Resolve a comma-separated `--<name>` policy list through the
    /// global registry; `Ok(None)` when the option is absent.
    pub fn get_policies(&self, name: &str) -> Result<Option<Vec<PolicyHandle>>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(spec) => PolicyRegistry::global()
                .parse_list(spec)
                .map(Some)
                .map_err(|e| format!("--{name}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), &["verbose"])
    }

    #[test]
    fn key_value_pairs() {
        let a = args(&["--seed", "42", "--policy=rfold", "tracefile"]);
        assert_eq!(a.get_u64("seed", 0), 42);
        assert_eq!(a.get("policy"), Some("rfold"));
        assert_eq!(a.positional(0), Some("tracefile"));
    }

    #[test]
    fn flags() {
        let a = args(&["--verbose", "--runs", "3"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize("runs", 1), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = args(&["--runs", "3", "--dry-run"]);
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn adjacent_flags() {
        let a = args(&["--a", "--b", "x"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("x"));
    }

    #[test]
    fn defaults() {
        let a = args(&[]);
        assert_eq!(a.get_usize("runs", 7), 7);
        assert_eq!(a.get_f64("scale", 1.5), 1.5);
        assert_eq!(a.get_str("name", "dflt"), "dflt");
    }

    #[test]
    fn durations_parse_with_suffixes() {
        assert_eq!(parse_duration_secs("500ms").unwrap(), 0.5);
        assert_eq!(parse_duration_secs("5s").unwrap(), 5.0);
        assert_eq!(parse_duration_secs("2m").unwrap(), 120.0);
        assert_eq!(parse_duration_secs("1h").unwrap(), 3600.0);
        assert_eq!(parse_duration_secs("7").unwrap(), 7.0);
        assert!(parse_duration_secs("5x").unwrap_err().contains("malformed"));
        assert!(parse_duration_secs("-1s").unwrap_err().contains(">= 0"));

        let a = args(&["--snapshot-every", "1h"]);
        assert_eq!(a.get_duration("snapshot-every", 0.0).unwrap(), 3600.0);
        assert_eq!(a.get_duration("absent", 9.0).unwrap(), 9.0);
        let b = args(&["--snapshot-every", "bogus"]);
        let err = b.get_duration("snapshot-every", 0.0).unwrap_err();
        assert!(err.contains("--snapshot-every"), "{err}");
    }

    #[test]
    fn policies_resolve_through_the_registry() {
        use crate::placement::builtins;
        let a = args(&["--policy", "ff", "--policies", "rfold, slurm"]);
        assert_eq!(
            a.get_policy("policy", builtins::RFOLD).unwrap(),
            builtins::FIRST_FIT
        );
        assert_eq!(
            a.get_policies("policies").unwrap().unwrap(),
            vec![builtins::RFOLD, builtins::HILBERT]
        );
        // Absent option → default / None.
        assert_eq!(
            a.get_policy("other", builtins::FOLDING).unwrap(),
            builtins::FOLDING
        );
        assert!(a.get_policies("other").unwrap().is_none());
        // Unknown names carry the known-keys list.
        let b = args(&["--policy", "bogus"]);
        let err = b.get_policy("policy", builtins::RFOLD).unwrap_err();
        assert!(err.contains("bogus") && err.contains("rfold"), "{err}");
    }
}
