//! Homomorphism verifier: checks that a variant's ring mappings are
//! faithful (paper §3.3 requires "the communication pattern can be
//! faithfully mapped onto the new shape").
//!
//! The folding constructions in `fold.rs` are believed-correct by
//! derivation; this module *checks* them — at commit time in debug builds
//! and exhaustively in the property-test suite. A variant is a valid
//! homomorphism of its job shape iff:
//!
//! 1. the logical→placed map is a bijection onto the placed box;
//! 2. every ring maps to a sequence whose consecutive nodes are adjacent
//!    in the placed box (unit step, or a wrap step on an axis with a
//!    wrap-around link); the *closing* step may be missing only for
//!    dimensions the fold made no cycle promise about (an open identity
//!    ring costs performance, not correctness);
//! 3. rings of the same parallelism dimension are vertex-disjoint (they
//!    run concurrently, §2).

use super::fold::{FoldKind, Variant};
use crate::topology::P3;

/// Verification failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VerifyError {
    NotBijective {
        at: P3,
    },
    /// Two consecutive ring nodes are not adjacent under available links.
    BrokenRing {
        dim: usize,
        from: P3,
        to: P3,
    },
    /// Rings of one dimension overlap (would serialize collectives).
    OverlappingRings {
        dim: usize,
        node: P3,
    },
}

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VerifyError::NotBijective { at } => write!(f, "mapping not bijective at {at}"),
            VerifyError::BrokenRing { dim, from, to } => {
                write!(f, "dim-{dim} ring broken between {from} and {to}")
            }
            VerifyError::OverlappingRings { dim, node } => {
                write!(f, "dim-{dim} rings overlap at {node}")
            }
        }
    }
}

/// Logical dimensions for which the fold construction *promises* a closed
/// cycle (and the verifier must therefore enforce closure).
pub fn promised_dims(variant: &Variant) -> [bool; 3] {
    let mut p = [false; 3];
    match &variant.kind {
        FoldKind::Identity => {}
        FoldKind::Refactor2 { axis, .. } => p[*axis] = true,
        FoldKind::Refactor3 { .. } => {
            let o = variant.orig.dims();
            for d in 0..3 {
                p[d] = o.0[d] > 1;
            }
        }
        FoldKind::HalveDouble { halved, doubled } => {
            p[*halved] = true;
            p[*doubled] = true;
        }
    }
    p
}

/// Step classification between two placed nodes: `(axis, is_wrap_step)`.
fn step_kind(a: P3, b: P3, ext: P3) -> Option<(usize, bool)> {
    let mut axis = None;
    for k in 0..3 {
        if a.0[k] != b.0[k] {
            if axis.is_some() {
                return None; // differs on two axes
            }
            axis = Some(k);
        }
    }
    let k = axis?; // identical points are not a step
    let d = a.0[k].abs_diff(b.0[k]);
    if d == 1 {
        Some((k, false))
    } else if d == ext.0[k] - 1 && ext.0[k] > 2 {
        Some((k, true)) // wrap step between the two extreme layers
    } else {
        None
    }
}

/// Verify a variant given which placed axes have wrap-around links
/// (`wrap[k]` true when the placed extent spans a full composed torus
/// dimension on axis `k`).
pub fn verify(variant: &Variant, wrap: [bool; 3]) -> Result<(), VerifyError> {
    let ext = variant.placed;
    // 1. bijectivity
    let mut hit = vec![false; ext.volume()];
    for l in variant.orig.dims().iter_box() {
        let p = variant.map_logical(l);
        let idx = p.index_in(ext);
        if hit[idx] {
            return Err(VerifyError::NotBijective { at: p });
        }
        hit[idx] = true;
    }
    if let Some(idx) = hit.iter().position(|&h| !h) {
        return Err(VerifyError::NotBijective {
            at: P3::from_index(idx, ext),
        });
    }

    // 2. ring adjacency + 3. per-dimension disjointness
    let promised = promised_dims(variant);
    let rings = variant.rings();
    for d in 0..3 {
        let mut used = vec![false; ext.volume()];
        for ring in rings.iter().filter(|r| r.dim == d) {
            for &n in &ring.nodes {
                let idx = n.index_in(ext);
                if used[idx] {
                    return Err(VerifyError::OverlappingRings { dim: d, node: n });
                }
                used[idx] = true;
            }
            let m = ring.nodes.len();
            if m < 2 {
                continue;
            }
            for w in 0..m {
                let a = ring.nodes[w];
                let b = ring.nodes[(w + 1) % m];
                let closing = w == m - 1;
                let ok = match step_kind(a, b, ext) {
                    Some((_, false)) => true,
                    Some((axis, true)) => wrap[axis],
                    None => false,
                };
                // A broken *closing* step is tolerated only for dimensions
                // the fold made no cycle promise about.
                if !ok && (!closing || promised[d]) {
                    return Err(VerifyError::BrokenRing { dim: d, from: a, to: b });
                }
            }
        }
    }
    Ok(())
}

/// Compute, per communicating logical dimension, `(ring length, closed?)`
/// under the given wrap availability — drives the JCT line-penalty.
pub fn ring_closures(variant: &Variant, wrap: [bool; 3]) -> Vec<(usize, bool)> {
    let ext = variant.placed;
    let mut out: Vec<(usize, bool)> = Vec::new();
    let rings = variant.rings();
    for d in 0..3 {
        let mut any = false;
        let mut closed = true;
        for ring in rings.iter().filter(|r| r.dim == d) {
            any = true;
            let m = ring.nodes.len();
            if m < 2 {
                continue;
            }
            for w in 0..m {
                let a = ring.nodes[w];
                let b = ring.nodes[(w + 1) % m];
                match step_kind(a, b, ext) {
                    Some((axis, true)) if wrap[axis] => {}
                    Some((_, false)) => {}
                    // A 2-ring over a single link closes trivially (the
                    // pair exchanges over the same cable both ways).
                    _ if m == 2 && a.torus_dist(b, ext) <= 1 => {}
                    _ => closed = false,
                }
            }
        }
        if any {
            out.push((variant.orig.dims().0[d], closed));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shape::fold::{enumerate_variants, FoldKind, Variant};
    use crate::shape::JobShape;

    #[test]
    fn identity_verifies_with_and_without_wrap() {
        let v = Variant::identity(JobShape::new(4, 4, 1));
        // Identity makes no cycle promise: open rings tolerated.
        verify(&v, [true, true, true]).unwrap();
        verify(&v, [false, false, false]).unwrap();
        // ...but the closure status is visible to the JCT model:
        let rc = ring_closures(&v, [false, false, false]);
        assert!(rc.iter().all(|&(_, closed)| !closed));
    }

    #[test]
    fn all_generated_variants_verify() {
        for s in [
            JobShape::new(18, 1, 1),
            JobShape::new(1, 6, 4),
            JobShape::new(4, 8, 2),
            JobShape::new(16, 1, 1),
            JobShape::new(2, 12, 1),
            JobShape::new(4, 4, 4),
            JobShape::new(6, 2, 2),
        ] {
            for v in enumerate_variants(s, 64) {
                verify(&v, v.requires_wrap)
                    .unwrap_or_else(|e| panic!("{s}: {v:?}: {e}"));
            }
        }
    }

    #[test]
    fn halve_double_requires_wrap() {
        let vs = enumerate_variants(JobShape::new(4, 8, 2), 64);
        let v = vs
            .iter()
            .find(|v| matches!(v.kind, FoldKind::HalveDouble { .. }))
            .unwrap();
        // Without wrap on the doubled axis the outer-pair ring breaks on an
        // *interior* step — a hard error, not a performance penalty.
        assert!(matches!(
            verify(v, [false, false, false]),
            Err(VerifyError::BrokenRing { .. })
        ));
        verify(v, v.requires_wrap).unwrap();
    }

    #[test]
    fn fold_cycles_close_without_wrap() {
        // Serpentine folds must close inside the box (no wrap needed).
        let vs = enumerate_variants(JobShape::new(18, 1, 1), 64);
        for v in vs.iter().filter(|v| v.kind != FoldKind::Identity) {
            verify(v, [false, false, false]).unwrap_or_else(|e| panic!("{v:?}: {e}"));
        }
    }

    #[test]
    fn ring_closures_reflect_wrap() {
        let v = Variant::identity(JobShape::new(6, 1, 1));
        let rc = ring_closures(&v, [false, false, false]);
        assert_eq!(rc, vec![(6, false)]);
        let rc = ring_closures(&v, [true, false, false]);
        assert_eq!(rc, vec![(6, true)]);
    }

    #[test]
    fn two_rings_close_trivially() {
        let v = Variant::identity(JobShape::new(2, 1, 1));
        let rc = ring_closures(&v, [false, false, false]);
        assert_eq!(rc, vec![(2, true)]);
    }

    #[test]
    fn folded_rings_close_without_wrap() {
        let vs = enumerate_variants(JobShape::new(12, 1, 1), 64);
        let v = vs
            .iter()
            .find(|v| matches!(v.kind, FoldKind::Refactor2 { .. }))
            .unwrap();
        let rc = ring_closures(v, [false, false, false]);
        assert_eq!(rc, vec![(12, true)]);
    }

    #[test]
    fn promised_dims_by_kind() {
        let id = Variant::identity(JobShape::new(4, 4, 4));
        assert_eq!(promised_dims(&id), [false; 3]);
        let vs = enumerate_variants(JobShape::new(4, 8, 2), 64);
        let hd = vs
            .iter()
            .find(|v| matches!(v.kind, FoldKind::HalveDouble { .. }))
            .unwrap();
        let p = promised_dims(hd);
        assert_eq!(p.iter().filter(|&&x| x).count(), 2);
    }

    #[test]
    fn corrupted_mapping_detected() {
        // A hand-made "variant" whose placed box is too big for the job
        // must fail bijectivity.
        let mut v = Variant::identity(JobShape::new(2, 2, 1));
        v.placed = P3([2, 2, 2]);
        assert!(matches!(
            verify(&v, [false; 3]),
            Err(VerifyError::NotBijective { .. })
        ));
    }
}
