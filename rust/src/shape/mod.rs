//! Job-shape algebra: factorization, rotation, and the paper's *folding*
//! technique (§3.3) — generating shape variants homomorphic to a job's
//! requested shape, with explicit communication-ring mappings that a
//! verifier checks rather than assumes.

pub mod cycles;
pub mod fold;
pub mod job_shape;
pub mod verify;

pub use fold::{FoldKind, Variant};
pub use job_shape::JobShape;
