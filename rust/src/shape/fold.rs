//! Folding: homomorphic shape-variant generation (paper §3.3).
//!
//! A variant describes how a job's *logical* shape maps onto a *placed*
//! box: which box to allocate, how each logical coordinate maps into it,
//! and how each parallelism dimension's ring becomes a cycle of adjacent
//! placed nodes. Constructions are explicit — `shape::verify` checks the
//! homomorphism property instead of assuming it.

use super::cycles::{box_cycle, serpentine_cycle};
use super::job_shape::JobShape;
use crate::topology::P3;

/// How a variant was derived from the original shape.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum FoldKind {
    /// Axis permutation only (rotation is default behaviour, §3.3).
    Identity,
    /// Logical axis `axis` re-factored into `p` (staying on `axis`) × `q`
    /// (moving to `q_axis`) via a serpentine Hamiltonian cycle — 1D→2D and
    /// 2D→3D folding.
    Refactor2 {
        axis: usize,
        q_axis: usize,
        p: usize,
        q: usize,
    },
    /// A 1D job's single axis re-factored onto all three placed axes via a
    /// 3D box Hamiltonian cycle (1D→3D folding).
    Refactor3 { p: usize, q: usize, r: usize },
    /// 3D→3D folding (Figure 2 right): `halved` axis loses half its length
    /// to a doubling of the `doubled` axis (which must have size 2; the
    /// 4×8×3 counterexample in the paper is excluded by construction).
    /// The outer layer-pair ring closes over a wrap-around link on the
    /// doubled axis, so this variant *requires* wrap there.
    HalveDouble { halved: usize, doubled: usize },
}

/// A placeable shape variant.
#[derive(Clone, Debug)]
pub struct Variant {
    /// The job's original logical shape.
    pub orig: JobShape,
    /// Box extents to allocate, *after* folding and rotation.
    pub placed: P3,
    pub kind: FoldKind,
    /// Axis permutation applied after folding: placed coordinate `k` takes
    /// folded coordinate `perm[k]`.
    pub perm: [usize; 3],
    /// Axes (of the placed box) on which the ring mappings only close if a
    /// wrap-around link exists. Placement must either provide wrap there
    /// or reject the variant.
    pub requires_wrap: [bool; 3],
}

/// One communication ring: the original parallelism dimension it belongs
/// to and its node sequence in placed-box coordinates (cycle order).
#[derive(Clone, Debug)]
pub struct Ring {
    pub dim: usize,
    pub nodes: Vec<P3>,
}

impl Variant {
    /// The trivial variant (no fold, no rotation).
    pub fn identity(shape: JobShape) -> Variant {
        Variant {
            orig: shape,
            placed: shape.dims(),
            kind: FoldKind::Identity,
            perm: [0, 1, 2],
            requires_wrap: [false; 3],
        }
    }

    /// Map a folded-space coordinate through the rotation.
    #[inline]
    fn rotate(&self, c: [usize; 3]) -> P3 {
        P3([c[self.perm[0]], c[self.perm[1]], c[self.perm[2]]])
    }

    /// Map a logical job coordinate to a placed-box coordinate.
    /// Panics (debug) if `l` is outside the original shape.
    pub fn map_logical(&self, l: P3) -> P3 {
        let o = self.orig.dims();
        debug_assert!((0..3).all(|a| l.0[a] < o.0[a]));
        let c = match &self.kind {
            FoldKind::Identity => l.0,
            FoldKind::Refactor2 { axis, q_axis, p, q } => {
                let cy = serpentine_cycle(*p, *q).expect("validated at build");
                let (u, v) = cy[l.0[*axis]];
                let mut c = l.0;
                c[*axis] = u;
                c[*q_axis] = v;
                c
            }
            FoldKind::Refactor3 { p, q, r } => {
                let axis = (0..3).find(|&a| o.0[a] > 1).expect("1D job");
                let cy = box_cycle(*p, *q, *r).expect("validated at build");
                let (u, v, w) = cy[l.0[axis]];
                [u, v, w]
            }
            FoldKind::HalveDouble { halved, doubled } => {
                let h = o.0[*halved];
                debug_assert_eq!(o.0[*doubled], 2);
                let mut c = l.0;
                if l.0[*halved] < h / 2 {
                    // First half: keeps its coordinates; doubled layers
                    // occupy z' ∈ {0, 1}.
                    c[*halved] = l.0[*halved];
                    c[*doubled] = l.0[*doubled];
                } else {
                    // Second half: reversed along the halved axis, mapped
                    // to the mirrored layers z' ∈ {3, 2}.
                    c[*halved] = h - 1 - l.0[*halved];
                    c[*doubled] = 3 - l.0[*doubled];
                }
                c
            }
        };
        self.rotate(c)
    }

    /// Generate every communication ring of the job, in placed coordinates.
    /// Dimension-`d` rings exist for every fiber of the other two logical
    /// dimensions when `orig[d] >= 2`.
    pub fn rings(&self) -> Vec<Ring> {
        let o = self.orig.dims();
        let mut out = Vec::new();
        for d in 0..3 {
            if o.0[d] < 2 {
                continue;
            }
            let (e, f) = match d {
                0 => (1, 2),
                1 => (0, 2),
                _ => (0, 1),
            };
            for ie in 0..o.0[e] {
                for jf in 0..o.0[f] {
                    let mut nodes = Vec::with_capacity(o.0[d]);
                    for k in 0..o.0[d] {
                        let mut l = [0usize; 3];
                        l[d] = k;
                        l[e] = ie;
                        l[f] = jf;
                        nodes.push(self.map_logical(P3(l)));
                    }
                    out.push(Ring { dim: d, nodes });
                }
            }
        }
        out
    }

    /// Ring lengths per communicating logical dimension: `(len, count)`.
    pub fn ring_profile(&self) -> Vec<(usize, usize)> {
        let o = self.orig.dims();
        (0..3)
            .filter(|&d| o.0[d] >= 2)
            .map(|d| (o.0[d], self.orig.size() / o.0[d]))
            .collect()
    }
}

const PERMS: [[usize; 3]; 6] = [
    [0, 1, 2],
    [0, 2, 1],
    [1, 0, 2],
    [1, 2, 0],
    [2, 0, 1],
    [2, 1, 0],
];

/// Generate all shape variants for a job, including rotations.
///
/// `max_dim` bounds placed dimensions (no point generating variants that
/// exceed the largest composable torus dimension).
pub fn enumerate_variants(shape: JobShape, max_dim: usize) -> Vec<Variant> {
    let mut base: Vec<Variant> = vec![Variant::identity(shape)];
    let o = shape.dims();
    let dimy = shape.dimensionality();

    match dimy {
        1 => {
            let axis = (0..3).find(|&a| o.0[a] > 1).unwrap();
            let q_axis = (0..3).find(|&a| a != axis).unwrap();
            let l = o.0[axis];
            if l % 2 == 0 {
                // 1D→2D: every 2-factorization (even product guaranteed).
                let mut p = 2;
                while p * p <= l {
                    if l % p == 0 {
                        let q = l / p;
                        if q >= 2 {
                            for (pp, qq) in [(p, q), (q, p)] {
                                let mut d = [1usize; 3];
                                d[axis] = pp;
                                d[q_axis] = qq;
                                base.push(Variant {
                                    orig: shape,
                                    placed: P3(d),
                                    kind: FoldKind::Refactor2 {
                                        axis,
                                        q_axis,
                                        p: pp,
                                        q: qq,
                                    },
                                    perm: [0, 1, 2],
                                    requires_wrap: [false; 3],
                                });
                                if p == q {
                                    break;
                                }
                            }
                        }
                    }
                    p += 1;
                }
                // 1D→3D: even 3-factorizations with a box cycle.
                for f in JobShape::factorizations(l, max_dim) {
                    let d = f.dims().0;
                    if d[0] >= 2 && box_cycle(d[0], d[1], d[2]).is_some() {
                        base.push(Variant {
                            orig: shape,
                            placed: P3(d),
                            kind: FoldKind::Refactor3 {
                                p: d[0],
                                q: d[1],
                                r: d[2],
                            },
                            perm: [0, 1, 2],
                            requires_wrap: [false; 3],
                        });
                    }
                }
            }
        }
        2 => {
            // Fold either communicating axis onto the free axis.
            let free = (0..3).find(|&a| o.0[a] == 1).unwrap();
            for axis in 0..3 {
                let l = o.0[axis];
                if axis == free || l < 4 || l % 2 != 0 {
                    continue;
                }
                for p in 2..=l / 2 {
                    if l % p != 0 {
                        continue;
                    }
                    let q = l / p;
                    if q < 2 {
                        continue;
                    }
                    let mut d = o.0;
                    d[axis] = p;
                    d[free] = q;
                    base.push(Variant {
                        orig: shape,
                        placed: P3(d),
                        kind: FoldKind::Refactor2 {
                            axis,
                            q_axis: free,
                            p,
                            q,
                        },
                        perm: [0, 1, 2],
                        requires_wrap: [false; 3],
                    });
                }
            }
        }
        3 => {
            // 3D→3D halve/double (Figure 2 right): needs an axis of size
            // exactly 2 to double and an even axis ≥ 4 to halve.
            for doubled in 0..3 {
                if o.0[doubled] != 2 {
                    continue;
                }
                for halved in 0..3 {
                    if halved == doubled || o.0[halved] < 4 || o.0[halved] % 2 != 0 {
                        continue;
                    }
                    let mut d = o.0;
                    d[halved] /= 2;
                    d[doubled] = 4;
                    let mut requires_wrap = [false; 3];
                    requires_wrap[doubled] = true;
                    base.push(Variant {
                        orig: shape,
                        placed: P3(d),
                        kind: FoldKind::HalveDouble { halved, doubled },
                        perm: [0, 1, 2],
                        requires_wrap,
                    });
                }
            }
        }
        _ => {}
    }

    // Expand rotations, drop over-large variants, dedup by placed+kind.
    let mut out: Vec<Variant> = Vec::new();
    let mut seen: Vec<(P3, FoldKind)> = Vec::new();
    for v in base {
        for perm in PERMS {
            let folded = v.placed; // base variants carry identity perm
            let placed = P3([folded.0[perm[0]], folded.0[perm[1]], folded.0[perm[2]]]);
            if placed.0.iter().any(|&d| d > max_dim) {
                continue;
            }
            let mut requires_wrap = [false; 3];
            for k in 0..3 {
                requires_wrap[k] = v.requires_wrap[perm[k]];
            }
            let key = (placed, v.kind.clone());
            if seen.contains(&key) {
                continue;
            }
            seen.push(key);
            out.push(Variant {
                orig: v.orig,
                placed,
                kind: v.kind.clone(),
                perm,
                requires_wrap,
            });
        }
    }
    out
}

/// Rotation-only variants (for policies without folding).
pub fn rotations_only(shape: JobShape, max_dim: usize) -> Vec<Variant> {
    enumerate_variants(shape, max_dim)
        .into_iter()
        .filter(|v| v.kind == FoldKind::Identity)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_maps_identically() {
        let v = Variant::identity(JobShape::new(4, 8, 2));
        assert_eq!(v.map_logical(P3([1, 2, 1])), P3([1, 2, 1]));
        assert_eq!(v.placed, P3([4, 8, 2]));
    }

    #[test]
    fn one_d_variants_include_2x9() {
        let vs = enumerate_variants(JobShape::new(18, 1, 1), 64);
        assert!(vs.iter().any(|v| {
            let mut d = v.placed.0;
            d.sort_unstable();
            d == [1, 2, 9] && v.kind != FoldKind::Identity
        }));
    }

    #[test]
    fn one_d_odd_has_no_cycle_folds() {
        let vs = enumerate_variants(JobShape::new(15, 1, 1), 64);
        // 15 odd → no grid cycle of odd length exists.
        assert!(vs.iter().all(|v| v.kind == FoldKind::Identity));
    }

    #[test]
    fn two_d_fold_paper_example() {
        // 1×6×4 folds to {4,2,3} (paper Figure 2 middle).
        let vs = enumerate_variants(JobShape::new(1, 6, 4), 64);
        assert!(
            vs.iter().any(|v| {
                let mut d = v.placed.0;
                d.sort_unstable();
                d == [2, 3, 4] && v.kind != FoldKind::Identity
            }),
            "{vs:?}"
        );
    }

    #[test]
    fn three_d_fold_paper_example() {
        // 4×8×2 folds to 4×4×4 (Figure 2 right).
        let vs = enumerate_variants(JobShape::new(4, 8, 2), 64);
        let v = vs
            .iter()
            .find(|v| v.placed == P3([4, 4, 4]) && v.kind != FoldKind::Identity)
            .expect("HalveDouble fold must exist");
        // wrap needed on the doubled axis.
        assert!(v.requires_wrap.iter().any(|&w| w));
    }

    #[test]
    fn three_d_counterexample_not_generated() {
        // 4×8×3 must NOT fold (paper's counterexample: the middle layer of
        // the odd dimension cannot map to any cycle).
        let vs = enumerate_variants(JobShape::new(4, 8, 3), 64);
        assert!(
            vs.iter().all(|v| v.kind == FoldKind::Identity),
            "no 3D fold may exist for 4x8x3"
        );
    }

    #[test]
    fn variants_preserve_volume() {
        for s in [
            JobShape::new(18, 1, 1),
            JobShape::new(1, 6, 4),
            JobShape::new(4, 8, 2),
            JobShape::new(12, 2, 1),
            JobShape::new(1, 1, 24),
        ] {
            for v in enumerate_variants(s, 64) {
                assert_eq!(v.placed.volume(), s.size(), "{v:?}");
            }
        }
    }

    #[test]
    fn map_logical_is_bijective() {
        for s in [
            JobShape::new(18, 1, 1),
            JobShape::new(1, 6, 4),
            JobShape::new(4, 8, 2),
            JobShape::new(2, 12, 1),
            JobShape::new(1, 1, 16),
        ] {
            for v in enumerate_variants(s, 64) {
                let mut seen = std::collections::HashSet::new();
                for l in s.dims().iter_box() {
                    let p = v.map_logical(l);
                    assert!(
                        (0..3).all(|a| p.0[a] < v.placed.0[a]),
                        "{v:?} {l} -> {p}"
                    );
                    assert!(seen.insert(p), "collision in {v:?} at {l}");
                }
                assert_eq!(seen.len(), s.size());
            }
        }
    }

    #[test]
    fn rotations_only_filters() {
        let vs = rotations_only(JobShape::new(4, 8, 2), 64);
        assert!(vs.iter().all(|v| v.kind == FoldKind::Identity));
        assert_eq!(vs.len(), 6); // all dims distinct → 6 rotations
    }

    #[test]
    fn max_dim_filters_placed() {
        let vs = enumerate_variants(JobShape::new(32, 1, 1), 16);
        assert!(vs.iter().all(|v| v.placed.0.iter().all(|&d| d <= 16)));
        // 32 = 2×16 or 4×8 still available.
        assert!(vs.iter().any(|v| v.kind != FoldKind::Identity));
    }

    #[test]
    fn ring_profile_counts() {
        let v = Variant::identity(JobShape::new(4, 6, 1));
        let prof = v.ring_profile();
        assert_eq!(prof, vec![(4, 6), (6, 4)]);
    }
}
