//! The job shape type: parallelism dimensions mapped to torus dimensions.

use crate::topology::P3;

/// A job's requested shape, e.g. `4×6×1` = four-way DP × six-way TP (§2).
/// Dimensions of size 1 carry no communication. Every dimension of size
/// ≥ 2 runs ring AllReduce collectives along its fibers.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct JobShape(pub P3);

impl JobShape {
    pub fn new(a: usize, b: usize, c: usize) -> JobShape {
        assert!(a >= 1 && b >= 1 && c >= 1, "shape dims must be >= 1");
        JobShape(P3([a, b, c]))
    }

    pub fn dims(&self) -> P3 {
        self.0
    }

    /// Total XPUs requested.
    pub fn size(&self) -> usize {
        self.0.volume()
    }

    /// Number of communicating dimensions (the paper's 1D/2D/3D job
    /// classification, §3.3).
    pub fn dimensionality(&self) -> usize {
        (0..3).filter(|&a| self.0 .0[a] > 1).count()
    }

    /// Canonical form: dimensions sorted descending. Two shapes with the
    /// same canonical form are rotations of each other.
    pub fn canonical(&self) -> JobShape {
        let mut d = self.0 .0;
        d.sort_unstable_by(|a, b| b.cmp(a));
        JobShape(P3(d))
    }

    /// All distinct axis permutations (≤ 6; fewer when dims repeat).
    pub fn rotations(&self) -> Vec<JobShape> {
        const PERMS: [[usize; 3]; 6] = [
            [0, 1, 2],
            [0, 2, 1],
            [1, 0, 2],
            [1, 2, 0],
            [2, 0, 1],
            [2, 1, 0],
        ];
        let mut out: Vec<JobShape> = Vec::with_capacity(6);
        for p in PERMS {
            let s = JobShape(P3([self.0 .0[p[0]], self.0 .0[p[1]], self.0 .0[p[2]]]));
            if !out.contains(&s) {
                out.push(s);
            }
        }
        out
    }

    /// All shapes (a, b, c) with `a*b*c == size`, unordered duplicates
    /// removed (a ≤ b ≤ c), each dimension capped at `max_dim`.
    pub fn factorizations(size: usize, max_dim: usize) -> Vec<JobShape> {
        let mut out = Vec::new();
        let mut a = 1;
        while a * a * a <= size {
            if size % a == 0 {
                let rest = size / a;
                let mut b = a;
                while b * b <= rest {
                    if rest % b == 0 {
                        let c = rest / b;
                        if c <= max_dim && b <= max_dim && a <= max_dim {
                            out.push(JobShape::new(a, b, c));
                        }
                    }
                    b += 1;
                }
            }
            a += 1;
        }
        out
    }
}

impl std::fmt::Display for JobShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensionality_classes() {
        assert_eq!(JobShape::new(18, 1, 1).dimensionality(), 1);
        assert_eq!(JobShape::new(1, 6, 4).dimensionality(), 2);
        assert_eq!(JobShape::new(4, 8, 2).dimensionality(), 3);
        assert_eq!(JobShape::new(1, 1, 1).dimensionality(), 0);
    }

    #[test]
    fn rotations_dedup() {
        assert_eq!(JobShape::new(4, 4, 4).rotations().len(), 1);
        assert_eq!(JobShape::new(4, 4, 2).rotations().len(), 3);
        assert_eq!(JobShape::new(2, 3, 4).rotations().len(), 6);
    }

    #[test]
    fn rotations_preserve_size() {
        let s = JobShape::new(2, 3, 4);
        for r in s.rotations() {
            assert_eq!(r.size(), 24);
        }
    }

    #[test]
    fn canonical_sorts_descending() {
        assert_eq!(
            JobShape::new(2, 8, 4).canonical(),
            JobShape::new(8, 4, 2)
        );
    }

    #[test]
    fn factorizations_of_12() {
        let f = JobShape::factorizations(12, 64);
        // (1,1,12) (1,2,6) (1,3,4) (2,2,3)
        assert_eq!(f.len(), 4);
        assert!(f.contains(&JobShape::new(1, 1, 12)));
        assert!(f.contains(&JobShape::new(2, 2, 3)));
    }

    #[test]
    fn factorizations_respect_cap() {
        let f = JobShape::factorizations(128, 16);
        assert!(f.iter().all(|s| s.dims().0.iter().all(|&d| d <= 16)));
        assert!(!f.is_empty());
        // 128 = 16*8 → (1,8,16) present, (1,1,128) filtered.
        assert!(f.contains(&JobShape::new(1, 8, 16)));
    }

    #[test]
    fn factorizations_of_prime() {
        let f = JobShape::factorizations(13, 64);
        assert_eq!(f, vec![JobShape::new(1, 1, 13)]);
        assert!(JobShape::factorizations(67, 64).is_empty());
    }
}
