//! Constructive cycle/path embeddings used by folding (§3.3).
//!
//! Folding maps a communication ring of length `L` onto a grid region so
//! the ring becomes a *cycle of adjacent nodes* — this is what lets a
//! non-multiple-of-N dimension close its ring without wrap-around links.
//!
//! * [`serpentine_cycle`]: Hamiltonian cycle of the `p×q` grid (exists iff
//!   `p*q` is even and `p, q ≥ 2`) — the "Y′ (circular)" construction in
//!   the paper's Figure 2.
//! * [`boustrophedon_path`]: Hamiltonian path of the `p×q` grid (always
//!   exists) — used to flatten a plane into a line for 3D refactoring and
//!   as the open-ring fallback.

/// Hamiltonian cycle of the `p×q` grid graph, returned in cycle order.
/// Returns `None` when no such cycle exists (`p*q` odd, or a dimension
/// < 2). Consecutive entries (and last→first) differ by exactly one unit
/// step; together they visit every cell exactly once.
pub fn serpentine_cycle(p: usize, q: usize) -> Option<Vec<(usize, usize)>> {
    if p < 2 || q < 2 || (p * q) % 2 != 0 {
        return None;
    }
    // Ensure the serpentine direction has an even number of rows; the
    // construction snakes through columns 1..q and returns via column 0.
    if p % 2 != 0 {
        // q must be even; build transposed and swap back.
        return serpentine_cycle(q, p)
            .map(|cy| cy.into_iter().map(|(r, c)| (c, r)).collect());
    }
    let mut cy = Vec::with_capacity(p * q);
    for r in 0..p {
        if r % 2 == 0 {
            for c in 1..q {
                cy.push((r, c));
            }
        } else {
            for c in (1..q).rev() {
                cy.push((r, c));
            }
        }
    }
    // p even ⇒ the snake ends at (p-1, 1); descend column 0 back to (0,0).
    for r in (0..p).rev() {
        cy.push((r, 0));
    }
    debug_assert_eq!(cy.len(), p * q);
    Some(cy)
}

/// Hamiltonian path of the `p×q` grid in boustrophedon order: row 0 left to
/// right, row 1 right to left, ... Consecutive entries are adjacent.
pub fn boustrophedon_path(p: usize, q: usize) -> Vec<(usize, usize)> {
    let mut path = Vec::with_capacity(p * q);
    for r in 0..p {
        if r % 2 == 0 {
            for c in 0..q {
                path.push((r, c));
            }
        } else {
            for c in (0..q).rev() {
                path.push((r, c));
            }
        }
    }
    path
}

/// Hamiltonian cycle of the `p×q×r` box: the 2D cycle over `(p, q*r)`
/// composed with a boustrophedon flattening of the `(q, r)` plane. Exists
/// iff the box has an even volume and supports the 2D construction.
pub fn box_cycle(p: usize, q: usize, r: usize) -> Option<Vec<(usize, usize, usize)>> {
    if p < 2 || q < 2 || r < 1 {
        return None;
    }
    if r == 1 {
        return serpentine_cycle(p, q).map(|cy| {
            cy.into_iter().map(|(a, b)| (a, b, 0)).collect()
        });
    }
    let plane = boustrophedon_path(q, r);
    let cy2 = serpentine_cycle(p, q * r)?;
    Some(
        cy2.into_iter()
            .map(|(a, t)| {
                let (b, c) = plane[t];
                (a, b, c)
            })
            .collect(),
    )
}

/// Check that a sequence of 2D points forms a closed cycle of unit steps
/// visiting distinct cells (test helper; the 3D variant lives in
/// `shape::verify`).
pub fn is_grid_cycle(cy: &[(usize, usize)]) -> bool {
    if cy.len() < 4 {
        return false;
    }
    let mut seen = std::collections::HashSet::new();
    for w in 0..cy.len() {
        let a = cy[w];
        let b = cy[(w + 1) % cy.len()];
        let d = a.0.abs_diff(b.0) + a.1.abs_diff(b.1);
        if d != 1 || !seen.insert(a) {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_2xm() {
        for m in 2..20 {
            let cy = serpentine_cycle(2, m).expect("2xm always has a cycle");
            assert_eq!(cy.len(), 2 * m);
            assert!(is_grid_cycle(&cy), "m={m} cy={cy:?}");
        }
    }

    #[test]
    fn cycle_even_odd_combinations() {
        for p in 2..8 {
            for q in 2..8 {
                let cy = serpentine_cycle(p, q);
                if (p * q) % 2 == 0 {
                    let cy = cy.expect("even grid must have a cycle");
                    assert_eq!(cy.len(), p * q);
                    assert!(is_grid_cycle(&cy), "p={p} q={q}");
                } else {
                    assert!(cy.is_none(), "odd grid {p}x{q} cannot have a cycle");
                }
            }
        }
    }

    #[test]
    fn no_cycle_in_degenerate_grids() {
        assert!(serpentine_cycle(1, 8).is_none());
        assert!(serpentine_cycle(8, 1).is_none());
        assert!(serpentine_cycle(3, 3).is_none());
    }

    #[test]
    fn path_visits_all_adjacent() {
        for (p, q) in [(1, 5), (3, 4), (4, 3), (2, 2), (5, 1)] {
            let path = boustrophedon_path(p, q);
            assert_eq!(path.len(), p * q);
            let distinct: std::collections::HashSet<_> = path.iter().collect();
            assert_eq!(distinct.len(), p * q);
            for w in path.windows(2) {
                let d = w[0].0.abs_diff(w[1].0) + w[0].1.abs_diff(w[1].1);
                assert_eq!(d, 1, "{p}x{q}: {:?}->{:?}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn box_cycle_3d() {
        for (p, q, r) in [(2, 2, 2), (2, 3, 2), (4, 2, 3), (2, 2, 3)] {
            let cy = box_cycle(p, q, r).expect("even box must cycle");
            assert_eq!(cy.len(), p * q * r);
            let distinct: std::collections::HashSet<_> = cy.iter().collect();
            assert_eq!(distinct.len(), p * q * r, "{p}x{q}x{r}");
            for w in 0..cy.len() {
                let a = cy[w];
                let b = cy[(w + 1) % cy.len()];
                let d = a.0.abs_diff(b.0) + a.1.abs_diff(b.1) + a.2.abs_diff(b.2);
                assert_eq!(d, 1, "{p}x{q}x{r} step {w}: {a:?}->{b:?}");
            }
        }
    }

    #[test]
    fn box_cycle_odd_volume_none() {
        assert!(box_cycle(3, 3, 3).is_none());
    }

    #[test]
    fn paper_example_18_as_2x9() {
        // The green 18×1×1 job in Figure 2 folds to a 2×9 cycle.
        let cy = serpentine_cycle(2, 9).unwrap();
        assert_eq!(cy.len(), 18);
        assert!(is_grid_cycle(&cy));
    }
}
