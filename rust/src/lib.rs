//! # RFold — co-adapting ML job shapes and cluster topology
//!
//! Reproduction of *"Toward Co-adapting Machine Learning Job Shape and
//! Cluster Topology"* (CS.DC 2025): a resource-allocation scheme for
//! multi-tenant 3D-torus ML clusters built from OCS-reconfigurable cubes.
//!
//! The crate is the **L3 coordinator** of a three-layer stack:
//!
//! * L1 — Pallas kernels (`python/compile/kernels/`) implement the batched
//!   plan-scoring hot spot, AOT-lowered to HLO text.
//! * L2 — the JAX plan-score graph (`python/compile/model.py`).
//! * L3 — this crate: torus topology + OCS model, shape folding, placement
//!   policies, the discrete-event cluster simulator, metrics, and the PJRT
//!   runtime that executes the AOT artifacts (Python never runs on the
//!   request path).
//!
//! Entry points: the [`coordinator`] leader loop, [`sim::Simulation`] for
//! trace-driven experiments, [`sim::sweep`] for result-cached work-queue
//! experiment grids over the [`trace::scenarios`] workload matrix, and the
//! `rfold` CLI (`rust/src/main.rs`).
//!
//! Placement policies are open: implement
//! [`placement::PlacementPolicy`], register a handle in the string-keyed
//! [`placement::PolicyRegistry`], and every driver (engine, sweeps, CLI,
//! live coordinator) can run the new policy by name. Scheduling decisions
//! are structured ([`placement::PlacementDecision`]) and observable
//! through [`sim::SchedulerObserver`] hooks.

pub mod coordinator;
pub mod metrics;
pub mod placement;
pub mod runtime;
pub mod shape;
pub mod sim;
pub mod topology;
pub mod trace;
pub mod util;

/// Total XPUs in the paper's evaluation cluster.
pub const CLUSTER_XPUS: usize = 4096;
